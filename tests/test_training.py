"""Training substrate: optimizers, microbatching, compression,
fault-tolerant resume, data determinism."""
import os
import tempfile
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, DataPipeline
from repro.models.config import ModelConfig
from repro.parallel import compression
from repro.training.loop import (TrainConfig, init_train_state,
                                 make_train_step, train)
from repro.training.optimizer import (OptimizerConfig, apply_opt, init_opt,
                                      lr_at)

pytestmark = pytest.mark.slow   # multi-minute JAX compile/run; excluded from tier-1

TINY = ModelConfig(name="tiny", n_layers=2, d_model=64, n_heads=4,
                   kv_heads=2, head_dim=16, d_ff=128, vocab=256,
                   dtype="float32", param_dtype="float32",
                   scan_min_layers=2)


def test_lr_schedule_shapes():
    cfg = OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                          schedule="cosine")
    assert float(lr_at(cfg, 0)) < float(lr_at(cfg, 10))
    assert float(lr_at(cfg, 10)) == pytest.approx(1e-3, rel=0.1)
    assert float(lr_at(cfg, 99)) < 1e-4


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_reduces_loss(name):
    ocfg = OptimizerConfig(name=name, lr=2e-3, warmup_steps=2,
                           total_steps=60)
    dcfg = DataConfig(vocab=256, seq_len=64, global_batch=8, seed=7)
    tcfg = TrainConfig(steps=50, log_every=49)
    out = train(TINY, ocfg, tcfg, dcfg, log_fn=lambda s: None)
    losses = dict(out["losses"])
    assert losses[0] - losses[49] > 0.3, losses


def test_microbatch_equivalence():
    """2 microbatches == full batch (same grads up to numerics)."""
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    dcfg = DataConfig(vocab=256, seq_len=32, global_batch=8, seed=3)
    batch = {k: jnp.asarray(v)
             for k, v in DataPipeline(dcfg).batch(0).items()}
    outs = {}
    for n_micro in (1, 2):
        tcfg = TrainConfig(steps=1, microbatches=n_micro)
        params, opt = init_train_state(TINY, ocfg, tcfg,
                                       jax.random.PRNGKey(0))
        # tcfg varies inside the loop, so a fresh jit per config is right
        step = jax.jit(make_train_step(TINY, ocfg, tcfg))  # mzc: ignore[MZC013]
        p2, _, m = step(params, opt, batch)
        outs[n_micro] = (p2, float(m["loss"]))
    assert outs[1][1] == pytest.approx(outs[2][1], rel=1e-5)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     outs[1][0], outs[2][0])
    assert max(jax.tree_util.tree_leaves(d)) < 1e-5


def test_grad_compression_error_feedback():
    g = {"w": jnp.linspace(-1, 1, 128).reshape(8, 16)}
    err = compression.init_error_feedback(g)
    ghat, err = compression.compressed_gradients(g, err)
    # one-shot quantization error is bounded by the int8 step
    step = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert float(jnp.max(jnp.abs(ghat["w"] - g["w"]))) <= step
    # error feedback: accumulated estimate converges to the truth
    total_true = jnp.zeros_like(g["w"])
    total_est = jnp.zeros_like(g["w"])
    err = compression.init_error_feedback(g)
    for _ in range(50):
        total_true += g["w"]
        ghat, err = compression.compressed_gradients(g, err)
        total_est += ghat["w"]
    rel = float(jnp.max(jnp.abs(total_est - total_true))
                / jnp.max(jnp.abs(total_true)))
    assert rel < 0.01


def test_training_with_compression_converges():
    ocfg = OptimizerConfig(lr=2e-3, warmup_steps=2, total_steps=40)
    dcfg = DataConfig(vocab=256, seq_len=64, global_batch=8, seed=7)
    tcfg = TrainConfig(steps=40, log_every=39, grad_compression=True)
    out = train(TINY, ocfg, tcfg, dcfg, log_fn=lambda s: None)
    losses = dict(out["losses"])
    assert losses[0] - losses[39] > 0.2


def test_failure_resume_bitwise_identical():
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=5, total_steps=40)
    dcfg = DataConfig(vocab=256, seq_len=64, global_batch=8, seed=7)
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        t1 = TrainConfig(steps=40, log_every=39, ckpt_every=20,
                         ckpt_dir=d1)
        ref = train(TINY, ocfg, t1, dcfg, log_fn=lambda s: None)
        t2 = TrainConfig(steps=40, log_every=39, ckpt_every=20,
                         ckpt_dir=d2)
        with pytest.raises(RuntimeError, match="injected failure"):
            train(TINY, ocfg, t2, dcfg, fail_at_step=20,
                  log_fn=lambda s: None)
        res = train(TINY, ocfg, t2, dcfg, log_fn=lambda s: None)
        assert dict(ref["losses"])[39] == pytest.approx(
            dict(res["losses"])[39], abs=1e-6)


def test_data_determinism_and_straggler_fallback():
    dcfg = DataConfig(vocab=128, seq_len=16, global_batch=4, seed=11,
                      straggler_timeout_s=0.01)
    p1, p2 = DataPipeline(dcfg), DataPipeline(dcfg)
    b1, b2 = p1.batch(17), p2.batch(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:],
                                  b1["labels"][:, :-1])
    # prefetcher never started -> timeout path -> synchronous fallback
    b3 = p1.next_batch(17)
    np.testing.assert_array_equal(b1["tokens"], b3["tokens"])
    assert p1.straggler_events == 1


def test_straggler_fallback_with_wedged_worker():
    """A RUNNING but wedged prefetch worker (sick host, not merely a
    never-started thread) must not block the training loop: next_batch
    times out, generates the batch synchronously, and logs exactly one
    straggler event — and the batch is still the pure (seed, step)
    function's output."""
    dcfg = DataConfig(vocab=128, seq_len=16, global_batch=4, seed=11,
                      straggler_timeout_s=0.05)
    p = DataPipeline(dcfg)
    release = threading.Event()
    real = p._src.batch
    main = threading.current_thread()

    def wedged(step):
        # wedge only the prefetch worker; the main thread's synchronous
        # fallback path must keep working
        if threading.current_thread() is not main:
            release.wait()
        return real(step)

    p._src.batch = wedged
    p.start(0)
    try:
        b = p.next_batch(0)
        assert p.straggler_events == 1
        np.testing.assert_array_equal(b["tokens"],
                                      DataPipeline(dcfg).batch(0)["tokens"])
        assert p._q.empty()  # the wedged worker really produced nothing
    finally:
        release.set()
        p.stop()
