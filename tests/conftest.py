"""Shared test plumbing: a lightweight per-test --timeout (SIGALRM-based,
no pytest-timeout dependency needed) and the repo root on sys.path so
tests can import the tools.mozart_check package."""
import os
import signal
import sys

import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

_own_timeout_option = False


def pytest_addoption(parser):
    global _own_timeout_option
    try:
        parser.addoption(
            "--timeout", type=float, default=0.0,
            help="per-test timeout in seconds (0 = off; SIGALRM-based, "
                 "main-thread Unix only)")
        _own_timeout_option = True
    except ValueError:
        # pytest-timeout (or similar) already registered --timeout;
        # defer to it entirely.
        pass


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_call(item):
    limit = item.config.getoption("--timeout") if _own_timeout_option \
        else None
    if not limit or not hasattr(signal, "SIGALRM"):
        yield
        return

    def _on_alarm(signum, frame):
        raise TimeoutError(f"test exceeded --timeout={limit}s")

    old = signal.signal(signal.SIGALRM, _on_alarm)
    signal.setitimer(signal.ITIMER_REAL, limit)
    try:
        yield
    finally:
        signal.setitimer(signal.ITIMER_REAL, 0)
        signal.signal(signal.SIGALRM, old)
