"""Model-zoo correctness: forward finiteness + prefill/decode equivalence
for every family and variant; scan/chunk formulation equivalences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import api, rglru, rwkv6, transformer as T, whisper as Wh
from repro.models.config import ModelConfig

pytestmark = pytest.mark.slow   # multi-minute JAX compile/run; excluded from tier-1

BASE = dict(n_layers=3, d_model=64, n_heads=4, kv_heads=2, head_dim=16,
            d_ff=128, vocab=97, dtype="float32", param_dtype="float32",
            scan_min_layers=2, capacity_factor=2.0)

VARIANTS = {
    "dense": ModelConfig(name="dense", **BASE),
    "qkv_bias_gelu": ModelConfig(name="b", qkv_bias=True, swiglu=False,
                                 **BASE),
    "swa_ring": ModelConfig(name="swa", window=8, **BASE),
    "moe": ModelConfig(name="moe", n_experts=4, top_k=2, **BASE),
    "deepseek_like": ModelConfig(name="dsk", n_experts=4, top_k=2,
                                 n_shared_experts=1, first_dense_layers=1,
                                 moe_d_ff=64, mla_q_rank=32, mla_kv_rank=16,
                                 mla_rope_dim=8, mtp=True, **BASE),
    "tied": ModelConfig(name="tied", tie_embeddings=True, **BASE),
    "mrope": ModelConfig(name="mrope", mrope_sections=(4, 6, 6),
                         **{**BASE, "head_dim": 32}),
}


@pytest.mark.parametrize("variant", sorted(VARIANTS))
def test_transformer_prefill_decode_equivalence(variant):
    cfg = VARIANTS[variant]
    cfg.validate()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    logits = T.forward(cfg, params, toks)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    last, cache = T.prefill(cfg, params, toks[:, :S - 4], max_len=S + 8)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(logits[:, S - 5]),
                               rtol=2e-2, atol=2e-2)
    for i in range(4):
        lg, cache = T.decode_step(cfg, params,
                                  toks[:, S - 4 + i:S - 3 + i], cache)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(logits[:, S - 4 + i]),
                                   rtol=3e-2, atol=3e-2)
    loss = T.loss_fn(cfg, params, {"tokens": toks, "labels": toks})
    assert np.isfinite(float(loss))


def test_transformer_vector_index_decode():
    """Mixed-length continuous-batching path: per-slot cache indices."""
    cfg = VARIANTS["dense"]
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, cfg.vocab)
    logits = T.forward(cfg, params, toks)
    # slot 0 holds 8 tokens, slot 1 holds 8 tokens of a shifted prompt
    last, c0 = T.prefill(cfg, params, toks[:, :8], max_len=32)
    cache = api.init_cache(cfg, 2, 32)
    cache["index"] = jnp.asarray([8, 0], jnp.int32)

    def set_slot(dst, src):
        def leaf(d, s):
            if d.ndim >= 3 and s.shape[1] == 1 and d.shape[1] == 2:
                return d.at[:, 0:1].set(s.astype(d.dtype))
            return d
        return jax.tree.map(leaf, dst, src)

    cache["segments"] = set_slot(cache["segments"], c0["segments"])
    lg, _ = T.decode_step(cfg, params, jnp.stack(
        [toks[0, 8:9], toks[0, 0:1]]), cache)
    np.testing.assert_allclose(np.asarray(lg[0, 0]),
                               np.asarray(logits[0, 8]),
                               rtol=3e-2, atol=3e-2)


def test_rglru_equivalences():
    cfg = ModelConfig(name="rg", family="rglru", n_layers=6, d_model=64,
                      n_heads=4, kv_heads=1, head_dim=16, d_ff=128,
                      vocab=97, lru_width=96, attn_every=3, window=8,
                      dtype="float32", param_dtype="float32")
    cfg.validate()
    params = rglru.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    logits = rglru.forward(cfg, params, toks)
    assert np.isfinite(np.asarray(logits)).all()
    last, cache = rglru.prefill(cfg, params, toks[:, :S - 4],
                                max_len=S + 8)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(logits[:, S - 5]),
                               rtol=2e-2, atol=2e-2)
    for i in range(4):
        lg, cache = rglru.decode_step(cfg, params,
                                      toks[:, S - 4 + i:S - 3 + i], cache)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(logits[:, S - 4 + i]),
                                   rtol=3e-2, atol=3e-2)


def test_rglru_assoc_scan_vs_sequential():
    a = jax.random.uniform(jax.random.PRNGKey(2), (2, 16, 8),
                           minval=0.1, maxval=0.99)
    b = jax.random.normal(jax.random.PRNGKey(3), (2, 16, 8))
    h0 = jax.random.normal(jax.random.PRNGKey(4), (2, 8))
    got = rglru.rglru_scan(a, b, h0)
    h = h0
    outs = []
    for t in range(16):
        h = a[:, t] * h + b[:, t]
        outs.append(h)
    np.testing.assert_allclose(np.asarray(got), np.stack(outs, 1),
                               rtol=1e-5, atol=1e-5)


def test_wkv_chunked_vs_sequential():
    B, S, H, D = 2, 37, 3, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    r = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, D))) * 0.9 + 0.05
    u = jax.random.normal(ks[4], (H, D)) * 0.1
    s0 = jax.random.normal(ks[5], (B, H, D, D)) * 0.1
    o1, sf1 = rwkv6.wkv_sequential(r, k, v, w, u, s0)
    o2, sf2 = rwkv6.wkv_chunked(r, k, v, w, u, s0, chunk=8)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(sf1), np.asarray(sf2),
                               rtol=1e-4, atol=1e-4)


def test_rwkv6_prefill_decode_equivalence():
    cfg = ModelConfig(name="rwkv", family="rwkv6", n_layers=3, d_model=64,
                      head_dim=16, d_ff=128, vocab=97, dtype="float32",
                      param_dtype="float32", wkv_chunk=8)
    params = rwkv6.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab)
    logits = rwkv6.forward(cfg, params, toks)
    assert np.isfinite(np.asarray(logits)).all()
    last, cache = rwkv6.prefill(cfg, params, toks[:, :20])
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(logits[:, 19]),
                               rtol=2e-2, atol=2e-2)
    for i in range(4):
        lg, cache = rwkv6.decode_step(cfg, params,
                                      toks[:, 20 + i:21 + i], cache)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(logits[:, 20 + i]),
                                   rtol=3e-2, atol=3e-2)


def test_whisper_prefill_decode_equivalence():
    cfg = ModelConfig(name="wh", family="whisper", n_layers=2,
                      n_enc_layers=2, d_model=64, n_heads=4, kv_heads=4,
                      d_ff=128, vocab=97, norm="layernorm", swiglu=False,
                      dtype="float32", param_dtype="float32")
    cfg.validate()
    params = Wh.init_params(cfg, jax.random.PRNGKey(0))
    B, Tf, S = 2, 20, 16
    frames = jax.random.normal(jax.random.PRNGKey(1),
                               (B, Tf, cfg.d_model)) * 0.1
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    logits = Wh.forward(cfg, params, frames, toks)
    assert np.isfinite(np.asarray(logits)).all()
    last, cache = Wh.prefill(cfg, params, frames, toks[:, :S - 4],
                             max_len=S + 8)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(logits[:, S - 5]),
                               rtol=2e-2, atol=2e-2)
    for i in range(4):
        lg, cache = Wh.decode_step(cfg, params,
                                   toks[:, S - 4 + i:S - 3 + i], cache)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(logits[:, S - 4 + i]),
                                   rtol=3e-2, atol=3e-2)


def test_attention_impl_agreement():
    """einsum == chunked == local (for windowed) on the same inputs."""
    from repro.models.common import attn_chunked, attn_einsum, attn_local
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, H, hd = 2, 64, 4, 16
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, 2, hd))
    v = jax.random.normal(ks[2], (B, S, 2, hd))
    a = attn_einsum(q, k, v, causal=True, window=None)
    b = attn_chunked(q, k, v, causal=True, window=None, chunk=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)
    aw = attn_einsum(q, k, v, causal=True, window=16)
    bw = attn_chunked(q, k, v, causal=True, window=16, chunk=16)
    cw = attn_local(q, k, v, window=16)
    np.testing.assert_allclose(np.asarray(aw), np.asarray(bw),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(aw), np.asarray(cw),
                               rtol=2e-4, atol=2e-4)
