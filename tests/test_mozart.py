"""The unified deployment API: declarative specs, artifact round-trip,
policy-driven serving."""

import dataclasses
import json
import subprocess
import sys

import pytest

from repro import mozart
from repro.core import codesign, operators, scenarios
from repro.core.fusion import GAConfig, Requirement
from repro.core.policy import (
    ExecutionPolicy,
    OperatorPolicy,
    policy_from_json,
)
from repro.core.pool import SAConfig

TINY_SA = SAConfig(iterations=1, inner_ga=GAConfig(population=3, generations=1))
TINY_GA = GAConfig(population=4, generations=2)


def tiny_spec(**kw):
    defaults = dict(
        networks={
            "resnet50": "resnet50",
            "opt_dec": operators.lm_operator_graph(
                operators.OPT_1_3B, 512, "decode", cache_len=512
            ),
        },
        scenario="chatbot",
        pool_size=4,
        seq=512,
        sa=TINY_SA,
        ga=TINY_GA,
        baselines=("best_homogeneous",),
    )
    defaults.update(kw)
    return mozart.MozartSpec(**defaults)


@pytest.fixture(scope="module")
def deployment():
    return mozart.compile(tiny_spec())


# -- scenarios ----------------------------------------------------------------


def test_scenario_registry():
    assert set(mozart.SCENARIOS) == {
        "chatbot",
        "summarization",
        "av_10ms",
        "av_33ms",
        "spec_decode",
    }
    assert mozart.get_scenario("chatbot").metric == "energy_cost"
    with pytest.raises(KeyError, match="unknown scenario"):
        mozart.get_scenario("nope")


def test_spec_decode_scenario_roles():
    s = mozart.get_scenario("spec_decode")
    assert isinstance(s, scenarios.SpecDecodeScenario)
    assert s.roles == ("draft", "target")
    # the k draft steps + 1 verify pass split one iteration's budget
    slot = s.accepted_per_iteration * s.requirement.tpot / (s.k + 1)
    assert s.requirement_for("draft").max_e2e == pytest.approx(slot)
    assert s.requirement_for("target").max_e2e == pytest.approx(slot)
    assert s.requirement_for("") == s.requirement
    with pytest.raises(ValueError, match="roles"):
        s.requirement_for("verifier")
    # iteration budget never exceeds the QoS: k drafts + verify <= TAR*tpot
    total = s.k * slot + slot
    assert total <= s.tar * s.requirement.tpot + 1e-12


def test_scenario_serialization_roundtrip():
    for s in mozart.SCENARIOS.values():
        assert scenarios.Scenario.from_dict(s.to_dict()) == s


# -- spec resolution ----------------------------------------------------------


def test_spec_resolution_objective_and_reqs():
    rs = tiny_spec().resolve()
    assert rs.objective == "energy_cost"  # from the chatbot scenario
    assert set(rs.networks) == {"resnet50", "opt_dec"}
    assert rs.reqs["resnet50"] == scenarios.CHATBOT
    assert tiny_spec(objective="edp").resolve().objective == "edp"
    assert tiny_spec(scenario=None).resolve().objective == "energy"


def test_spec_per_network_overrides():
    spec = tiny_spec(
        networks={
            "a": mozart.NetworkSpec(workload="resnet50", scenario="av_10ms"),
            "b": mozart.NetworkSpec(workload="resnet50", requirement=Requirement(e2e=1.0)),
        },
    )
    rs = spec.resolve()
    assert rs.reqs["a"] == scenarios.AV_FAST
    assert rs.reqs["b"] == Requirement(e2e=1.0)


def test_spec_specdec_roles_resolve():
    spec = tiny_spec(
        networks={
            "draft": mozart.NetworkSpec(workload="opt66b_decode", role="draft"),
            "tgt": mozart.NetworkSpec(workload="opt66b_prefill", role="target"),
        },
        scenario="spec_decode",
    )
    rs = spec.resolve()
    s = mozart.get_scenario("spec_decode")
    assert rs.reqs["draft"] == s.requirement_for("draft")
    assert rs.reqs["tgt"] == s.requirement_for("target")


def test_spec_validation_errors():
    with pytest.raises(ValueError, match="at least one network"):
        tiny_spec(networks={}).resolve()
    with pytest.raises(ValueError, match="unknown baselines"):
        tiny_spec(baselines=("nope",)).resolve()
    with pytest.raises(KeyError, match="unknown workload"):
        tiny_spec(networks={"x": "not_a_workload"}).resolve()


def test_spec_conflicting_metrics_need_explicit_objective():
    edp_scen = scenarios.Scenario("custom", "edp", Requirement(e2e=1.0))
    nets = {
        "a": mozart.NetworkSpec(workload="resnet50", scenario="av_10ms"),
        "b": mozart.NetworkSpec(workload="vit_b16", scenario=edp_scen),
    }
    with pytest.raises(ValueError, match="disagree on the metric"):
        tiny_spec(networks=nets, scenario=None).resolve()
    rs = tiny_spec(networks=nets, scenario=None, objective="edp").resolve()
    assert rs.objective == "edp"


def test_spec_serialization_roundtrip():
    spec = tiny_spec()
    spec2 = mozart.MozartSpec.from_dict(spec.to_dict())
    assert spec2.resolve() == spec.resolve()
    assert spec2.to_dict() == spec.to_dict()


def test_spec_workers_fold_into_sa():
    rs = tiny_spec(workers=3, executor="thread").resolve()
    assert rs.sa.workers == 3
    assert rs.sa.executor == "thread"
    assert TINY_SA.workers is None  # caller's config untouched


# -- compile + artifact round-trip -------------------------------------------


def test_compile_produces_designs_and_policies(deployment):
    dep = deployment
    assert set(dep.designs) == {"resnet50", "opt_dec"}
    assert set(dep.policies) == {"resnet50", "opt_dec"}
    assert dep.objective == "energy_cost"
    assert len(dep.pool) == 4
    assert dep.best_homogeneous("resnet50") is not None
    assert dep.unconstrained("resnet50") is None  # not requested
    for d in dep.designs.values():
        assert d.pnr.placements
        assert d.fusion.value > 0


def test_artifact_roundtrip_bit_exact(deployment, tmp_path):
    dep = deployment
    path = dep.save(tmp_path / "dep.json")
    dep2 = mozart.load(path)
    # bit-exact: metrics, pool labels, per-stage configs, P&R, summary
    assert dep2.metrics() == dep.metrics()
    assert dep2.pool_labels() == dep.pool_labels()
    for name in dep.designs:
        s1 = dep.designs[name].fusion.solution
        s2 = dep2.designs[name].fusion.solution
        assert [o.cfg.label for o in s1.stages] == [o.cfg.label for o in s2.stages]
        assert [o.t_cmp for o in s1.stages] == [o.t_cmp for o in s2.stages]
        assert dep2.designs[name].pnr.to_dict() == dep.designs[name].pnr.to_dict()
    assert dep2.summary() == dep.summary()
    # idempotent: a reloaded artifact re-serializes byte-identically
    assert dep2.to_json() == dep.to_json()


def test_artifact_schema_guard(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text(json.dumps({"schema": "other/v9"}))
    with pytest.raises(ValueError, match="not a mozart deployment"):
        mozart.load(p)


def test_compile_raises_on_infeasible():
    spec = tiny_spec(
        networks={
            "impossible": mozart.NetworkSpec(
                workload="opt66b_prefill",
                requirement=Requirement(e2e=1e-12),
            ),
        },
        baselines=(),
    )
    with pytest.raises(RuntimeError, match="no feasible design"):
        mozart.compile(spec)


def test_summary_reductions(deployment):
    summary = deployment.summary()
    assert summary["objective"] == "energy_cost"
    assert summary["geomean_value"] > 0
    row = summary["per_network"]["resnet50"]
    assert row["vs_best_homogeneous"] > 0
    assert "vs_unconstrained" not in row
    assert summary["chiplet_reuse"]


# -- policy round-trip + consumption -----------------------------------------


def test_policy_json_roundtrip(deployment):
    pol = deployment.policy("opt_dec")
    pol2 = policy_from_json(pol.to_json())
    assert pol2 == pol
    blob = json.loads(pol.to_json())
    assert blob["fusion"] == pol.fusion_flags()


def test_policy_json_flag_guard(deployment):
    blob = json.loads(deployment.policy("opt_dec").to_json())
    blob["fusion"]["flash_attention"] = not blob["fusion"]["flash_attention"]
    with pytest.raises(ValueError, match="fusion flags"):
        policy_from_json(json.dumps(blob))


def test_load_policy_from_artifact_and_bare_file(deployment, tmp_path):
    art = deployment.save(tmp_path / "dep.json")
    pol = mozart.load_policy(art, "opt_dec")
    assert pol == deployment.policy("opt_dec")
    with pytest.raises(KeyError):
        mozart.load_policy(art, "nope")
    with pytest.raises(ValueError, match="name one"):
        mozart.load_policy(art)  # two networks -> ambiguous
    bare = tmp_path / "policy.json"
    bare.write_text(pol.to_json())
    assert mozart.load_policy(bare) == pol


def fake_policy(groups):
    ops = [
        OperatorPolicy(
            group=g,
            batch=b,
            tp=tp,
            memory="HBM3",
            chiplet="WS-pe64-glb512K-2D",
            fused="+" in g,
        )
        for g, b, tp in groups
    ]
    return ExecutionPolicy(network="n", interval_s=1e-3, operators=ops)


def test_apply_policy_mapping():
    from repro.launch.serve import apply_policy
    from repro.models.config import ModelConfig

    pol = fake_policy(
        [
            ("norm1+qkv_proj+attention", 2, 2),
            ("mlp", 16, 1),
        ]
    )
    mcfg, kw, lines = apply_policy(pol, ModelConfig(), max_batch=8, n_devices=1)
    assert mcfg.attn_impl == "flash"  # fusion flag applied
    assert kw["max_batch"] == 8  # min(cli cap 8, sensitive 16)
    assert kw["decode_batch"] == 2  # agnostic batch bounds decode
    assert kw["mesh_tp"] == 1  # tp=2 but 1 device -> unsharded
    text = "\n".join(lines)
    assert "flash_attention=True" in text
    assert "decode_batch=2" in text
    _, kw2, _ = apply_policy(pol, ModelConfig(), max_batch=8, n_devices=2)
    assert kw2["mesh_tp"] == 2


def test_apply_policy_fused_mlp_norm_applied():
    """fused_mlp / fused_norm are real substrate toggles now: they map to
    ModelConfig.mlp_impl / norm_impl and are logged as applied (the
    old '(advisory)' path is gone)."""
    from repro.launch.serve import apply_policy
    from repro.models.config import ModelConfig

    pol = fake_policy(
        [
            ("qkv_proj+attention", 2, 1),
            ("norm2+mlp", 16, 1),
        ]
    )
    assert pol.fusion_flags() == {
        "flash_attention": True,
        "fused_mlp": True,
        "fused_norm": True,
    }
    mcfg, kw, lines = apply_policy(pol, ModelConfig(), max_batch=8, n_devices=1)
    assert mcfg.mlp_impl == "fused"
    assert mcfg.norm_impl == "fused"
    text = "\n".join(lines)
    assert "fused_mlp->mlp_impl=fused" in text
    assert "fused_norm->norm_impl=fused" in text
    assert "advisory" not in text
    # families without the dispatch hook log an explicit no-op instead
    # of claiming application
    rcfg = ModelConfig(family="rglru", attn_every=2)
    mcfg_r, _, lines_r = apply_policy(pol, rcfg, max_batch=8, n_devices=1)
    assert mcfg_r.mlp_impl == "dense" and mcfg_r.norm_impl == "ref"
    assert "fused_mlp(no hook" in "\n".join(lines_r)


def test_apply_policy_no_fusion():
    from repro.launch.serve import apply_policy
    from repro.models.config import ModelConfig

    pol = fake_policy([("attention", 4, 1), ("mlp", 4, 1)])
    mcfg, kw, _ = apply_policy(pol, ModelConfig(), max_batch=4, n_devices=1)
    assert mcfg.attn_impl == "auto"  # unfused policy leaves dispatch alone
    assert kw["max_batch"] == 4


# -- satellite: baseline budget derivation -----------------------------------


def test_best_homogeneous_uses_caller_budget(monkeypatch):
    seen = []

    def spy(graph, chiplet, objective="energy", req=None, ga=None):
        seen.append(ga)
        return None

    monkeypatch.setattr(codesign, "homogeneous_design", spy)
    g = operators.paper_workloads(seq=512)["resnet50"]
    codesign.best_homogeneous_design(g, ga=TINY_GA)
    assert all(ga == TINY_GA for ga in seen)
    seen.clear()
    codesign.best_homogeneous_design(g)
    # no caller budget -> the full default, not a silently trimmed one
    assert all(ga == GAConfig() for ga in seen)
    assert GAConfig().generations == 24


# -- serve --policy smoke (subprocess; slow) ---------------------------------


@pytest.mark.slow
def test_serve_policy_smoke(deployment, tmp_path):
    art = deployment.save(tmp_path / "dep.json")
    cmd = [
        sys.executable,
        "-m",
        "repro.launch.serve",
        "--arch",
        "smollm-135m",
        "--smoke",
        "--policy",
        str(art),
        "--policy-network",
        "opt_dec",
        "--requests",
        "2",
        "--max-new",
        "4",
    ]
    out = subprocess.run(cmd, capture_output=True, text=True, timeout=900, check=True).stdout
    assert "policy network=opt1.3b_decode" in out
    assert "fusion flags: flash_attention=True" in out
    assert "policy microbatch" in out
    assert "batch_sensitive_batch" in out


@pytest.mark.slow
def test_engine_decode_subbatching():
    """decode_batch < max_batch round-robins lock-step decode without
    changing any request's tokens."""
    import jax
    import numpy as np

    from repro.models import api
    from repro.models.config import ModelConfig
    from repro.serving.engine import Request, ServingEngine

    cfg = ModelConfig(
        name="tiny",
        n_layers=2,
        d_model=64,
        n_heads=4,
        kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=97,
        dtype="float32",
        param_dtype="float32",
        scan_min_layers=2,
    )
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    prompts = [np.arange(4 + i, dtype=np.int32) + i for i in range(4)]

    def run(decode_batch):
        eng = ServingEngine(cfg, params, max_batch=4, max_len=64, decode_batch=decode_batch)
        reqs = [Request(rid=i, prompt=p, max_new_tokens=6) for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        return [r.out_tokens for r in reqs], eng.stats["decode_steps"]

    full, steps_full = run(4)
    sub, steps_sub = run(2)
    assert sub == full
    assert steps_sub > steps_full  # sub-batching trades steps for width
