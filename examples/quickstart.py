"""Quickstart: run the Mozart codesign stack on one network and deploy
the result as an execution policy.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import operators
from repro.core.chiplets import default_pool
from repro.core.codesign import design_for_network
from repro.core.costmodel import system_cost
from repro.core.fusion import GAConfig, Requirement
from repro.core.policy import policy_from_design


def main() -> None:
    # 1. lower a network to Mozart's operator IR (OPT-1.3B decode here)
    graph = operators.lm_operator_graph(
        operators.OPT_1_3B, seq=2048, phase="decode", cache_len=2048)
    print(f"network: {graph.network}  "
          f"ops={len(graph.operators)} (x repeats)  "
          f"GFLOPs/token={graph.total_flops / 1e9:.1f}")

    # 2. layers 2-4: GA fusion + iso-latency convex hull + place&route,
    #    under a 150 ms TPOT requirement, cost-aware objective
    design = design_for_network(
        graph, default_pool(), objective="energy_cost",
        req=Requirement(tpot=0.15),
        ga=GAConfig(population=8, generations=5))
    sol = design.fusion.solution
    print(f"\nBASIC: E/token={sol.energy_per_sample * 1e3:.3f} mJ  "
          f"TPOT={sol.delay_e2e * 1e3:.2f} ms  "
          f"throughput={sol.throughput:.0f} tok/s  hw=${sol.hw_cost_usd:.0f}")
    print(f"P&R: {design.pnr.width:.1f}x{design.pnr.height:.1f} mm "
          f"(feasible={design.pnr.feasible}, "
          f"wire={design.pnr.wirelength_mm:.0f} mm)")
    cost = system_cost(sol.stages, volume=1e6,
                       n_networks_sharing={
                           o.cfg.chiplet.label: 200 for o in sol.stages})
    print(f"unit cost: die=${cost.die:.0f} pkg=${cost.packaging:.0f} "
          f"nre/unit=${cost.nre_per_unit:.2f}")

    # 3. the solution as stage assignments
    print("\nstage plan (operator-level heterogeneity):")
    for st in sol.stages:
        print(f"  {st.group_name[:44]:44s} -> {st.cfg.label} "
              f"(x{st.repeat})")

    # 4. deploy: execution policy for the JAX substrate
    pol = policy_from_design(design)
    print("\nexecution policy:", pol.fusion_flags(),
          f"attn_batch={pol.batch_agnostic_batch}",
          f"mlp_batch={pol.batch_sensitive_batch}")


if __name__ == "__main__":
    main()
