"""Quickstart: one declarative spec in, one deployment artifact out.

`mozart.compile` runs the four-layer codesign stack (SA pool -> GA
fusion -> iso-latency convex hull -> P&R) for every network of the
spec, and the resulting `Deployment` is a reusable JSON artifact:
designs, execution policies, and baseline comparisons all round-trip
bit-exact through `save`/`load`, and `repro.launch.serve --policy`
consumes the policy directly.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import tempfile

from repro import mozart
from repro.core import operators
from repro.core.fusion import GAConfig
from repro.core.pool import SAConfig


def main() -> None:
    # 1. declare WHAT to build: two phases of OPT-1.3B serving under the
    #    chatbot scenario (TTFT 2.5 s / TPOT 150 ms, energy-x-cost
    #    metric of record).  Budgets here are trimmed for a fast demo;
    #    drop the sa/ga overrides to search at the full defaults.
    spec = mozart.MozartSpec(
        networks={
            "opt1.3b_prefill": operators.lm_operator_graph(
                operators.OPT_1_3B, seq=2048, phase="prefill"
            ),
            "opt1.3b_decode": operators.lm_operator_graph(
                operators.OPT_1_3B, seq=2048, phase="decode", cache_len=2048
            ),
        },
        scenario="chatbot",
        pool_size=4,
        sa=SAConfig(iterations=3, inner_ga=GAConfig(population=4, generations=1)),
        ga=GAConfig(population=8, generations=5),
        baselines=("best_homogeneous",),
    )

    # 2. compile: spec -> Deployment (the whole ecosystem).
    dep = mozart.compile(spec)
    print(f"objective: {dep.objective}")
    print(f"pool: {', '.join(dep.pool_labels())}")

    # 3. paper-style reductions: per-network values + baseline ratios.
    summary = dep.summary()
    for name, row in summary["per_network"].items():
        vs = row.get("vs_best_homogeneous")
        vs_s = f"{vs:.2f}x vs best single-SKU" if vs else "no baseline"
        mj = row["energy_per_sample"] * 1e3
        line = (
            f"  {name}: value={row['value']:.4g}  E/sample={mj:.3f} mJ  "
            f"throughput={row['throughput']:.0f}/s  ({vs_s})"
        )
        print(line)
    print(f"geomean value: {summary['geomean_value']:.4g}")
    print(f"chiplet reuse: {summary['chiplet_reuse']}")

    # 4. the artifact round-trips: a codesign run is a reusable file.
    with tempfile.TemporaryDirectory() as tmp:
        path = dep.save(os.path.join(tmp, "deployment.json"))
        dep2 = mozart.load(path)
        assert dep2.to_json() == dep.to_json(), "artifact must round-trip"
        print(f"artifact round-trip OK ({os.path.getsize(path)} bytes)")

    # 5. deploy: the decode policy the serving engine consumes
    #    (serve --policy deployment.json --policy-network opt1.3b_decode).
    pol = dep.policy("opt1.3b_decode")
    line = (
        f"decode policy: fusion={pol.fusion_flags()}  "
        f"attn_batch={pol.batch_agnostic_batch}  "
        f"mlp_batch={pol.batch_sensitive_batch}  tp={pol.tp_degree}"
    )
    print(line)


if __name__ == "__main__":
    main()
