"""Serving example: continuous-batching engine + speculative decoding on
a reduced config — the substrate the paper's §6.2.1 case study models.

    PYTHONPATH=src python examples/serve_spec_decode.py
"""
import time

import jax
import numpy as np

from repro import configs
from repro.models import api, transformer
from repro.serving.engine import Request, ServingEngine
from repro.serving.specdec import spec_decode_greedy


def main() -> None:
    mcfg = configs.get_smoke_config("smollm-135m")
    params = api.init_params(mcfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    # --- continuous batching
    eng = ServingEngine(mcfg, params, max_batch=4, max_len=96)
    for i in range(8):
        eng.submit(Request(
            rid=i,
            prompt=rng.integers(0, mcfg.vocab, size=int(
                rng.integers(4, 12))).astype(np.int32),
            max_new_tokens=12))
    t0 = time.time()
    eng.run()
    occ = float(np.mean(eng.stats["slot_occupancy"]))
    print(f"continuous batching: {eng.stats['tokens_out']} tokens in "
          f"{time.time() - t0:.1f}s, occupancy {occ:.2f}")

    # --- speculative decoding (draft = 1/4-depth model)
    dcfg = mcfg.replace(n_layers=max(1, mcfg.n_layers // 4))
    dparams = api.init_params(dcfg, jax.random.PRNGKey(1))
    tf = jax.jit(lambda t: transformer.forward(mcfg, params, t))
    df = jax.jit(lambda t: transformer.forward(dcfg, dparams, t))
    prompt = rng.integers(0, mcfg.vocab, size=10).astype(np.int32)
    out, stats = spec_decode_greedy(tf, df, prompt, k=5,
                                    max_new_tokens=20)
    print(f"specdec: {len(out)} tokens, accept={stats.acceptance_rate:.2f},"
          f" tokens/iter={stats.tokens_per_iteration:.2f}"
          f" (draft latency-critical, verifier batched — Insight 3)")


if __name__ == "__main__":
    main()
