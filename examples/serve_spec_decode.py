"""Speculative decoding as a first-class Mozart scenario (paper §6.2.1)
plus the serving substrate it deploys onto.

Stage 1 codesigns the draft/target pair declaratively: the
`spec_decode` scenario hands the latency-critical draft and the
batched verifier each their own requirement split from the chatbot
TPOT budget (Insight 3), and `mozart.compile` returns one artifact
with both policies.  Stage 2 runs the actual JAX substrate: the
continuous-batching engine and draft/target speculative decoding.

    PYTHONPATH=src python examples/serve_spec_decode.py
"""
import time

import jax
import numpy as np

from repro import configs, mozart
from repro.core import operators
from repro.core.fusion import GAConfig
from repro.core.operators import OPT_1_3B
from repro.core.pool import SAConfig
from repro.models import api, transformer
from repro.serving.engine import Request, ServingEngine
from repro.serving.specdec import spec_decode_greedy


def codesign() -> None:
    scen = mozart.get_scenario("spec_decode")
    d_req = scen.requirement_for("draft")
    t_req = scen.requirement_for("target")
    print(f"scenario: {scen.name} ({scen.description})")
    print(f"  draft  per-token deadline: {d_req.max_e2e * 1e3:.1f} ms")
    print(f"  target verify-pass deadline: {t_req.max_e2e * 1e3:.1f} ms")

    spec = mozart.MozartSpec(
        networks={
            "draft": mozart.NetworkSpec(
                workload=operators.lm_operator_graph(
                    OPT_1_3B, 2048, "decode", cache_len=2048),
                role="draft"),
            "target_verify": mozart.NetworkSpec(
                workload=operators.lm_operator_graph(
                    operators.OPT_66B, seq=scen.k + 1, phase="prefill"),
                role="target"),
        },
        scenario="spec_decode",
        pool_size=4,
        sa=SAConfig(iterations=2,
                    inner_ga=GAConfig(population=4, generations=1)),
        ga=GAConfig(population=6, generations=3),
        baselines=(),
    )
    dep = mozart.compile(spec)
    for name in dep.networks:
        sol = dep.designs[name].fusion.solution
        pol = dep.policy(name)
        print(f"  {name}: lat={sol.delay_e2e * 1e3:.1f} ms "
              f"batch(agnostic/sensitive)="
              f"{pol.batch_agnostic_batch}/{pol.batch_sensitive_batch}")


def substrate() -> None:
    mcfg = configs.get_smoke_config("smollm-135m")
    params = api.init_params(mcfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    # --- continuous batching
    eng = ServingEngine(mcfg, params, max_batch=4, max_len=96)
    for i in range(8):
        eng.submit(Request(
            rid=i,
            prompt=rng.integers(0, mcfg.vocab, size=int(
                rng.integers(4, 12))).astype(np.int32),
            max_new_tokens=12))
    t0 = time.time()
    eng.run()
    occ = float(np.mean(eng.stats["slot_occupancy"]))
    print(f"continuous batching: {eng.stats['tokens_out']} tokens in "
          f"{time.time() - t0:.1f}s, occupancy {occ:.2f}")

    # --- speculative decoding (draft = 1/4-depth model)
    dcfg = mcfg.replace(n_layers=max(1, mcfg.n_layers // 4))
    dparams = api.init_params(dcfg, jax.random.PRNGKey(1))
    # one-shot demo pair — constructed once per example run
    tf = jax.jit(lambda t: transformer.forward(mcfg, params, t))  # mzc: ignore[MZC013]
    df = jax.jit(lambda t: transformer.forward(dcfg, dparams, t))  # mzc: ignore[MZC013]
    prompt = rng.integers(0, mcfg.vocab, size=10).astype(np.int32)
    out, stats = spec_decode_greedy(tf, df, prompt, k=5,
                                    max_new_tokens=20)
    print(f"specdec: {len(out)} tokens, accept={stats.acceptance_rate:.2f},"
          f" tokens/iter={stats.tokens_per_iteration:.2f}"
          f" (draft latency-critical, verifier batched — Insight 3)")


def main() -> None:
    codesign()
    substrate()


if __name__ == "__main__":
    main()
