"""End-to-end training driver: train a reduced SmolLM on the synthetic
corpus for a few hundred steps with checkpointing, gradient compression,
and (if >1 device) a data+tensor-parallel mesh.

    PYTHONPATH=src python examples/train_smollm.py --steps 300
"""
import argparse
import tempfile

from repro import configs
from repro.data.pipeline import DataConfig
from repro.training.loop import TrainConfig, train
from repro.training.optimizer import OptimizerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    mcfg = configs.get_smoke_config("smollm-135m")
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=20,
                           total_steps=args.steps)
    dcfg = DataConfig(vocab=mcfg.vocab, seq_len=args.seq,
                      global_batch=args.batch, seed=0)
    with tempfile.TemporaryDirectory() as ckpt:
        tcfg = TrainConfig(steps=args.steps, log_every=25,
                           ckpt_every=100, ckpt_dir=ckpt,
                           microbatches=2, grad_compression=True)
        out = train(mcfg, ocfg, tcfg, dcfg)
    first, last = out["losses"][0][1], out["losses"][-1][1]
    print(f"\nloss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"({out['wall_s']:.0f}s)")
    assert last < first, "training must reduce loss"


if __name__ == "__main__":
    main()
