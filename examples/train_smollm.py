"""End-to-end training driver: train a reduced SmolLM on the synthetic
corpus for a few hundred steps with checkpointing, gradient compression,
and (if >1 device) a data+tensor-parallel mesh.

    PYTHONPATH=src python examples/train_smollm.py --steps 300

An optional Mozart deployment artifact drives the microbatch split:
`--policy deployment.json` divides the global batch by the policy's
batch-sensitive microbatch (Insight 2 applied to the training loop).
"""
import argparse
import tempfile

from repro import configs
from repro.data.pipeline import DataConfig
from repro.training.loop import TrainConfig, train
from repro.training.optimizer import OptimizerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--policy", default=None, metavar="DEPLOYMENT_JSON",
                    help="mozart artifact; microbatch count follows the "
                         "policy's batch_sensitive_batch")
    ap.add_argument("--policy-network", default=None,
                    help="which network's policy to take from a "
                         "multi-network artifact")
    args = ap.parse_args()

    microbatches = 2
    if args.policy:
        from repro.mozart import load_policy
        pol = load_policy(args.policy, args.policy_network)
        # Smallest microbatch count that divides the global batch AND
        # keeps each microbatch <= the policy's batch-sensitive size
        # (the training loop reshapes to (microbatches, batch/m, ...)).
        sens = max(1, pol.batch_sensitive_batch)
        microbatches = next(m for m in range(1, args.batch + 1)
                            if args.batch % m == 0
                            and args.batch // m <= sens)
        print(f"[train] policy {pol.network}: "
              f"batch_sensitive_batch={pol.batch_sensitive_batch} -> "
              f"{microbatches} microbatches of "
              f"{args.batch // microbatches}")

    mcfg = configs.get_smoke_config("smollm-135m")
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=20,
                           total_steps=args.steps)
    dcfg = DataConfig(vocab=mcfg.vocab, seq_len=args.seq,
                      global_batch=args.batch, seed=0)
    with tempfile.TemporaryDirectory() as ckpt:
        tcfg = TrainConfig(steps=args.steps, log_every=25,
                           ckpt_every=100, ckpt_dir=ckpt,
                           microbatches=microbatches,
                           grad_compression=True)
        out = train(mcfg, ocfg, tcfg, dcfg)
    first, last = out["losses"][0][1], out["losses"][-1][1]
    print(f"\nloss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"({out['wall_s']:.0f}s)")
    assert last < first, "training must reduce loss"


if __name__ == "__main__":
    main()
