"""Constraint-aware codesign for the autonomous-vehicle scenario
(paper §6.2.2): perception backbones under a hard 33 ms DET deadline at
batch=1, optimizing energy x $ — as one declarative `MozartSpec` whose
three networks share a single annealed chiplet pool.

    PYTHONPATH=src python examples/codesign_av.py
"""
from repro import mozart
from repro.core.fusion import GAConfig
from repro.core.pool import SAConfig


def main() -> None:
    scen = mozart.get_scenario("av_33ms")
    print(f"scenario: {scen.name} ({scen.description}), "
          f"deadline={scen.requirement.e2e * 1e3:.0f} ms, "
          f"metric={scen.metric}")

    spec = mozart.MozartSpec(
        networks={n: n for n in ("resnet50", "mobilenetv3", "vit_b16")},
        scenario="av_33ms",
        pool_size=4,
        sa=SAConfig(iterations=3,
                    inner_ga=GAConfig(population=4, generations=1,
                                      fixed_batch=1)),
        ga=GAConfig(population=8, generations=4, fixed_batch=1),
        baselines=("best_homogeneous",),
    )
    dep = mozart.compile(spec)
    print(f"shared pool: {', '.join(dep.pool_labels())}")

    for name, d in dep.designs.items():
        sol = d.fusion.solution
        skus = sorted({o.cfg.chiplet.label for o in sol.stages})
        print(f"\n{name}: lat={sol.delay_e2e * 1e3:.2f} ms (<= 33 ms) "
              f"E/frame={sol.energy_per_sample * 1e3:.2f} mJ "
              f"hw=${sol.hw_cost_usd:.0f}")
        print(f"  chiplets: {', '.join(skus)}")
        print(f"  P&R {d.pnr.width:.0f}x{d.pnr.height:.0f} mm, "
              f"feasible={d.pnr.feasible}")

    summary = dep.summary()
    reuse = summary["chiplet_reuse"]
    print(f"\nchiplet reuse across the ecosystem: {reuse} "
          f"(shared SKUs amortize NRE, paper §6.2.2)")


if __name__ == "__main__":
    main()
