"""Constraint-aware codesign for the autonomous-vehicle scenario
(paper §6.2.2): perception backbones under a hard 33 ms DET deadline at
batch=1, optimizing energyx$.

    PYTHONPATH=src python examples/codesign_av.py
"""
from repro.core import operators, scenarios
from repro.core.chiplets import default_pool
from repro.core.codesign import design_for_network
from repro.core.fusion import GAConfig


def main() -> None:
    scen = scenarios.AUTONOMOUS_VEHICLE_33MS
    print(f"scenario: {scen.name} ({scen.description}), "
          f"deadline={scen.requirement.e2e * 1e3:.0f} ms, "
          f"metric={scen.metric}")
    ws = operators.paper_workloads()
    for name in ("resnet50", "mobilenetv3", "vit_b16"):
        d = design_for_network(
            ws[name], default_pool(), objective=scen.metric,
            req=scen.requirement,
            ga=GAConfig(population=8, generations=4, fixed_batch=1))
        sol = d.fusion.solution
        skus = sorted({o.cfg.chiplet.label for o in sol.stages})
        print(f"\n{name}: lat={sol.delay_e2e * 1e3:.2f} ms "
              f"(<= 33 ms) E/frame={sol.energy_per_sample * 1e3:.2f} mJ "
              f"hw=${sol.hw_cost_usd:.0f}")
        print(f"  chiplets: {', '.join(skus)}")
        print(f"  P&R {d.pnr.width:.0f}x{d.pnr.height:.0f} mm, "
              f"feasible={d.pnr.feasible}")


if __name__ == "__main__":
    main()
